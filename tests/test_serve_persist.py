"""The durable serving tier (repro.serve.persist + GPServer persistence,
budgeting, restart): kernel-spec round trips, ulp-exact state round trips,
corrupt-checkpoint rejection, LRU eviction/lazy-reload parity, and the
acceptance test — kill a server, `GPServer.load()` a new one from the same
store, and serve bit-identical predictions."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve
from repro.checkpoint.manager import CheckpointCorruptError
from repro.gp import SparseGPRegression, get, suff_stats
from repro.gp.stats import ExactBatch
from repro.serve import (GPServer, PERSIST_SCHEMA, StateStore,
                         kernel_from_spec, kernel_spec)
from repro.serve.server import BUDGET_ENV


def _f64(tree):
    return jax.tree.map(lambda x: x.astype(jnp.float64), tree)


def _fitted(seed=0, N=160, M=10, Q=1, phase=0.0):
    key = jax.random.PRNGKey(seed)
    X = jnp.sort(jax.random.uniform(key, (N, Q), jnp.float64, -3.0, 3.0), axis=0)
    Y = jnp.sin(2.0 * X + phase) + 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1), (N, 1), jnp.float64)
    kernel = get("rbf")(Q)
    params = {"kern": _f64(kernel.init(1.1, 0.7)), "Z": X[:: N // M][:M],
              "log_beta": jnp.asarray(2.0, jnp.float64)}
    stats = suff_stats(kernel, params["kern"], ExactBatch(X, Y, params["Z"]))
    return kernel, serve.build_state(kernel, params, stats), X


def _assert_states_identical(a, b):
    """Every leaf bit-identical AND dtype-identical — persistence must be a
    ulp-exact round trip, not merely allclose."""
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (path, x), (_, y) in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, f"{path}: {x.dtype} != {y.dtype}"
        assert x.shape == y.shape, f"{path}: {x.shape} != {y.shape}"
        np.testing.assert_array_equal(x, y, err_msg=str(path))


# ---------------------------------------------------------------------------
# kernel specs
# ---------------------------------------------------------------------------

def _same_kernel(a, b) -> bool:
    """Structural equality: composites are plain classes (no __eq__), so
    compare type + input_dim + recursive parts."""
    if type(a) is not type(b) or a.input_dim != b.input_dim:
        return False
    pa, pb = getattr(a, "parts", None), getattr(b, "parts", None)
    if pa is None or pb is None:
        return pa is pb and a == b  # leaf kernels are frozen dataclasses
    return len(pa) == len(pb) and all(map(_same_kernel, pa, pb))


@pytest.mark.parametrize("make", [
    lambda: get("rbf")(2),
    lambda: get("linear")(3),
    lambda: get("sum")(get("rbf")(2), get("linear")(2)),
    lambda: get("product")(get("rbf")(1), get("matern32")(1)),
    lambda: get("sum")(get("product")(get("rbf")(2), get("linear")(2)),
                       get("matern52")(2)),
])
def test_kernel_spec_round_trips(make):
    kernel = make()
    spec = kernel_spec(kernel)
    json.dumps(spec)  # must be JSON-able as-is (it rides the manifest)
    rebuilt = kernel_from_spec(spec)
    assert _same_kernel(rebuilt, kernel)
    assert kernel_spec(rebuilt) == spec


def test_kernel_spec_rejects_garbage():
    with pytest.raises(ValueError, match="malformed"):
        kernel_from_spec({"input_dim": 2})
    with pytest.raises(KeyError):
        kernel_from_spec({"name": "no-such-kernel", "input_dim": 2})


# ---------------------------------------------------------------------------
# StateStore round trip
# ---------------------------------------------------------------------------

def test_store_round_trip_is_ulp_exact(tmp_path):
    kernel, state, _ = _fitted()
    store = StateStore(tmp_path)
    step = store.save("m", kernel, state)
    assert step == 1 and store.has("m") and store.names() == ("m",)
    kernel2, state2 = store.load("m")
    assert kernel2 == kernel
    _assert_states_identical(state2, state)
    # every field — including both Cholesky factors — survived exactly
    for field in ("Z", "log_beta", "L", "LA", "Kuu_inv_mean"):
        np.testing.assert_array_equal(np.asarray(getattr(state2, field)),
                                      np.asarray(getattr(state, field)),
                                      err_msg=field)
    # and the manifest-only byte accounting matches the live pytree
    assert store.nbytes("m") == state.nbytes


def test_store_versions_and_composite_kernels(tmp_path):
    kernel = get("sum")(get("rbf")(1), get("linear")(1))
    _, state, _ = _fitted()
    # graft the composite's param structure onto the fitted state's shapes
    kern_p = _f64(kernel.init())
    state = state._replace(kern=kern_p)
    store = StateStore(tmp_path, keep=2)
    assert store.save("m", kernel, state) == 1
    bumped = state._replace(log_beta=state.log_beta + 1.0)
    assert store.save("m", kernel, bumped) == 2
    kernel2, loaded = store.load("m")
    assert _same_kernel(kernel2, kernel)  # composite spec round-tripped
    _assert_states_identical(loaded, bumped)  # newest step wins


def test_store_rejects_unsafe_names(tmp_path):
    kernel, state, _ = _fitted()
    store = StateStore(tmp_path)
    for bad in ("../escape", "a/b", "", ".hidden"):
        with pytest.raises((ValueError, FileNotFoundError)):
            store.save(bad, kernel, state)


def test_load_missing_model_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        StateStore(tmp_path).load("never-saved")


# ---------------------------------------------------------------------------
# corrupt checkpoints are rejected, loudly
# ---------------------------------------------------------------------------

def _saved_store(tmp_path):
    kernel, state, X = _fitted()
    store = StateStore(tmp_path)
    store.save("m", kernel, state)
    return store, kernel, state, X


def test_truncated_arrays_rejected(tmp_path):
    store, *_ = _saved_store(tmp_path)
    npz = next((tmp_path / "m").glob("step_*/arrays.npz"))
    npz.write_bytes(npz.read_bytes()[: npz.stat().st_size // 3])
    with pytest.raises(CheckpointCorruptError):
        store.load("m")


def test_garbage_manifest_rejected(tmp_path):
    store, *_ = _saved_store(tmp_path)
    manifest = next((tmp_path / "m").glob("step_*/manifest.json"))
    manifest.write_text("{not json")
    with pytest.raises(CheckpointCorruptError, match="manifest"):
        store.load("m")


def test_wrong_persist_schema_rejected(tmp_path):
    store, *_ = _saved_store(tmp_path)
    manifest = next((tmp_path / "m").glob("step_*/manifest.json"))
    doc = json.loads(manifest.read_text())
    doc["extra"]["persist_schema"] = PERSIST_SCHEMA + 999
    manifest.write_text(json.dumps(doc))
    with pytest.raises(CheckpointCorruptError, match="persist_schema"):
        store.load("m")
    with pytest.raises(CheckpointCorruptError, match="persist_schema"):
        store.load_meta("m")


def test_missing_leaf_rejected(tmp_path):
    store, *_ = _saved_store(tmp_path)
    npz = next((tmp_path / "m").glob("step_*/arrays.npz"))
    arrays = dict(np.load(npz, allow_pickle=False))
    removed = next(k for k in arrays if k.endswith("LA"))
    del arrays[removed]
    np.savez(npz, **arrays)
    with pytest.raises(CheckpointCorruptError, match="LA"):
        store.load("m")


# ---------------------------------------------------------------------------
# budgeted server: evict -> lazy reload -> identical predictions
# ---------------------------------------------------------------------------

def test_eviction_reload_serves_identically(tmp_path):
    models = {f"m{i}": _fitted(seed=i, phase=0.3 * i) for i in range(4)}
    Xt = models["m0"][2][:13]
    reference = GPServer()
    for name, (kernel, state, _) in models.items():
        reference.register(name, kernel=kernel, state=state)
    expected = {name: reference.predict(name, Xt) for name in models}

    state_bytes = models["m0"][1].nbytes
    budgeted = GPServer(store=StateStore(tmp_path),
                        budget_bytes=2 * state_bytes + 8)
    for name, (kernel, state, _) in models.items():
        budgeted.register(name, kernel=kernel, state=state)
    # walk all models twice in round-robin: every access past the first two
    # evicts someone and (second lap) lazily reloads the victim
    for _ in range(2):
        for name in models:
            mean, var = budgeted.predict(name, Xt)
            np.testing.assert_array_equal(np.asarray(mean),
                                          np.asarray(expected[name][0]),
                                          err_msg=name)
            np.testing.assert_array_equal(np.asarray(var),
                                          np.asarray(expected[name][1]),
                                          err_msg=name)
    m = budgeted.metrics()
    assert m["evictions"] > 0 and m["lazy_loads"] > 0
    assert m["peak_resident_bytes"] <= m["budget_bytes"]
    assert m["resident_bytes"] <= m["budget_bytes"]
    assert m["registered"] == 4 and m["resident_models"] <= 2
    budgeted.close()
    reference.close()


def test_update_on_evicted_state_reloads_then_folds(tmp_path):
    (k0, s0, X0), (k1, s1, _) = _fitted(seed=0), _fitted(seed=1, phase=0.5)
    srv = GPServer(store=StateStore(tmp_path), budget_bytes=s0.nbytes + 8)
    srv.register("a", kernel=k0, state=s0)
    srv.register("b", kernel=k1, state=s1)  # evicts a (dirty -> persisted)
    assert srv.metrics()["evictions"] == 1
    srv.update("a", X0[:7], jnp.sin(2.0 * X0[:7]))  # reloads a, folds, swaps
    n_after = float(srv.state("a").stats.n)
    assert n_after == float(s0.stats.n) + 7
    assert srv.metrics()["lazy_loads"] >= 1
    srv.close()


def test_budget_requires_store_and_env_knob(tmp_path, monkeypatch):
    with pytest.raises(ValueError, match="store"):
        GPServer(budget_bytes=1 << 20)
    with pytest.raises(ValueError, match="positive"):
        GPServer(store=StateStore(tmp_path), budget_bytes=0)
    kernel, state, _ = _fitted()
    monkeypatch.setenv(BUDGET_ENV, str(state.nbytes + 8))
    srv = GPServer(store=StateStore(tmp_path))  # budget picked up from env
    assert srv.budget_bytes == state.nbytes + 8
    srv.register("a", kernel=kernel, state=state)
    srv.register("b", kernel=kernel, state=state)
    assert srv.metrics()["evictions"] == 1
    assert srv.metrics()["resident_bytes"] <= srv.budget_bytes
    srv.close()


# ---------------------------------------------------------------------------
# the acceptance test: kill-and-restart serves bit-identical predictions
# ---------------------------------------------------------------------------

def test_kill_and_restart_is_bit_identical(tmp_path):
    store = StateStore(tmp_path)
    models = {f"m{i}": _fitted(seed=i, phase=0.4 * i) for i in range(3)}
    Xt = models["m0"][2][:9]

    srv = GPServer(store=store)
    for name, (kernel, state, _) in models.items():
        srv.register(name, kernel=kernel, state=state)
    # mutate one model after registration — save_all must capture the
    # LATEST state, not the registered snapshot
    Xu = models["m1"][2][:11]
    srv.update("m1", Xu, jnp.cos(Xu))
    saved = srv.save_all()
    assert set(saved) == set(models)  # all dirty -> all written
    assert srv.save_all() == ()  # second call: everything clean, no writes
    expected = {name: srv.predict(name, Xt) for name in models}
    before = {name: srv.state(name) for name in models}
    srv.close()
    del srv  # the "kill"

    restarted = GPServer.load(store)
    assert restarted.models() == tuple(sorted(models))
    assert restarted.metrics()["resident_bytes"] == 0  # cold start
    for name in models:
        mean, var = restarted.predict(name, Xt)
        np.testing.assert_array_equal(np.asarray(mean),
                                      np.asarray(expected[name][0]),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(var),
                                      np.asarray(expected[name][1]),
                                      err_msg=name)
        _assert_states_identical(restarted.state(name), before[name])
    # submit() path serves the restarted states too
    fut = restarted.submit("m1", Xt)
    np.testing.assert_array_equal(np.asarray(fut.result(timeout=30)[0]),
                                  np.asarray(expected["m1"][0]))
    restarted.close()


def test_restart_under_budget_stays_under_budget(tmp_path):
    store = StateStore(tmp_path)
    models = {f"m{i}": _fitted(seed=i) for i in range(5)}
    srv = GPServer(store=store)
    for name, (kernel, state, _) in models.items():
        srv.register(name, kernel=kernel, state=state)
    srv.save_all()
    srv.close()

    state_bytes = models["m0"][1].nbytes
    budget = 2 * state_bytes + 8
    restarted = GPServer.load(store, budget_bytes=budget)
    assert restarted.metrics()["registered"] == 5
    Xt = models["m0"][2][:5]
    for name in models:  # touch everything: forces evict/reload churn
        restarted.predict(name, Xt)
    m = restarted.metrics()
    assert m["peak_resident_bytes"] <= budget
    assert m["resident_models"] <= 2
    restarted.close()
