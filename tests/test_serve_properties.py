"""Property tests for the online-update algebra (`repro.serve.online`).

The serving tier's durability story leans on one algebraic fact: SuffStats
is a commutative monoid over datapoints, so streaming arbitrary chunkings of
a dataset through `serve.online.update` must land on the same posterior as
the one-shot build — regardless of partition, order, or statistics backend.
These tests state that as properties over RANDOM partitions rather than the
hand-picked splits in tests/test_serve.py.

Runs under real `hypothesis` when installed; otherwise the deterministic
fallback in tests/_hypothesis_compat.py draws a fixed pseudo-random spread
of examples (no shrinking, same properties).
"""
import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st
from repro import serve
from repro.core.psi_stats import SuffStats
from repro.gp import get, suff_stats
from repro.gp.stats import ExactBatch
from repro.serve import online

Q, D, M = 2, 2, 8


def _f64(tree):
    return jax.tree.map(lambda x: x.astype(jnp.float64), tree)


def _data(seed: int, N: int):
    key = jax.random.PRNGKey(seed)
    X = jax.random.normal(key, (N, Q), jnp.float64)
    w = jnp.arange(1, D + 1, dtype=jnp.float64)
    Y = jnp.sin(X.sum(axis=1))[:, None] * w + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (N, D), jnp.float64)
    Z = X[:: max(N // M, 1)][:M]
    kern = _f64(get("rbf")(Q).init(1.3, 0.8))
    params = {"kern": kern, "Z": Z,
              "log_beta": jnp.asarray(2.0, jnp.float64)}
    return X, Y, params


def _one_shot(kernel, params, X, Y):
    stats = suff_stats(kernel, params["kern"], ExactBatch(X, Y, params["Z"]))
    return serve.build_state(kernel, params, stats)


def _partition(n: int, pieces: int, seed: int):
    """Split range(n) into `pieces` non-empty contiguous chunks at
    pseudo-random cut points, then shuffle the chunk order."""
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.choice(np.arange(1, n), size=pieces - 1, replace=False))
    bounds = [0, *cuts.tolist(), n]
    chunks = [(bounds[i], bounds[i + 1]) for i in range(pieces)]
    rng.shuffle(chunks)
    return chunks


def _assert_states_close(a, b, rtol=1e-8, atol=1e-8):
    for x, y, name in zip(a.stats, b.stats, SuffStats._fields):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol,
                                   atol=atol, err_msg=f"stats.{name}")
    for name in ("L", "LA", "Kuu_inv_mean"):
        np.testing.assert_allclose(np.asarray(getattr(a, name)),
                                   np.asarray(getattr(b, name)), rtol=1e-6,
                                   atol=1e-6, err_msg=name)


# ---------------------------------------------------------------------------
# streamed chunk folds commute/associate with the one-shot build
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(40, 90),
       pieces=st.integers(2, 5),
       backend=st.sampled_from(["jnp", "fused"]))
def test_streamed_partition_matches_one_shot(seed, n, pieces, backend):
    """Any partition of the data, streamed chunk-by-chunk in any order
    through online.update, equals the one-shot fit: the monoid fold is
    associative and commutative, so the serving tier may absorb data in
    whatever order requests arrive."""
    X, Y, params = _data(seed, n)
    kernel = get("rbf")(Q)
    chunks = _partition(n, pieces, seed + 1)
    lo, hi = chunks[0]
    state = _one_shot(kernel, params, X[lo:hi], Y[lo:hi])
    for lo, hi in chunks[1:]:
        state = online.update(kernel, state, X[lo:hi], Y[lo:hi],
                              backend=backend)
    scratch = _one_shot(kernel, params, X, Y)
    assert float(state.stats.n) == float(scratch.stats.n) == n
    _assert_states_close(state, scratch)
    # and the served predictions agree where it matters
    Xt = X[: min(9, n)]
    mean_a, var_a = serve.predict(kernel, state, Xt)
    mean_b, var_b = serve.predict(kernel, scratch, Xt)
    np.testing.assert_allclose(np.asarray(mean_a), np.asarray(mean_b),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(var_a), np.asarray(var_b),
                               rtol=1e-6, atol=1e-8)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(40, 80),
       pieces=st.integers(2, 4))
def test_fold_order_is_immaterial(seed, n, pieces):
    """Two different shuffles of the same chunk set reach bitwise-close
    states: update is a fold over a commutative monoid, not a sequence-
    sensitive recursion."""
    X, Y, params = _data(seed, n)
    kernel = get("rbf")(Q)
    chunks = _partition(n, pieces, seed + 1)

    def fold(order):
        lo, hi = order[0]
        s = _one_shot(kernel, params, X[lo:hi], Y[lo:hi])
        for lo, hi in order[1:]:
            s = online.update(kernel, s, X[lo:hi], Y[lo:hi])
        return s

    _assert_states_close(fold(chunks), fold(list(reversed(chunks))))


# ---------------------------------------------------------------------------
# update then downdate is the identity (monoid inverse)
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(40, 80),
       b=st.integers(5, 30), backend=st.sampled_from(["jnp", "fused"]))
def test_update_downdate_roundtrip(seed, n, b, backend):
    """downdate(update(s, chunk), chunk) == s to f64 tolerance, for random
    base sets and random extra chunks on both statistics backends."""
    X, Y, params = _data(seed, n + b)
    kernel = get("rbf")(Q)
    base = _one_shot(kernel, params, X[:n], Y[:n])
    up = online.update(kernel, base, X[n:], Y[n:], backend=backend)
    back = online.downdate(kernel, up, X[n:], Y[n:], backend=backend)
    assert float(back.stats.n) == float(base.stats.n) == n
    _assert_states_close(back, base)
