"""Seeded lint violation (ANL001): platform dispatch read at IMPORT time.
The backend snapshot below goes stale under jax.distributed init or test
reordering — exactly the bug class `interpret_mode()` exists to prevent.
Linted as source text with a virtual repro/ path; never imported."""
import jax

BACKEND = jax.default_backend()  # ANL001: must be read at call time


def uses_backend() -> str:
    return BACKEND
