"""Seeded concurrency violation (ANL006): a lock-guarded attribute touched
without the lock. `put` establishes that `self._table` is shared state
guarded by `self._lock`; `drop` then mutates it lock-free — the race
class guard inference exists to catch (the generalized ANL002). Analyzed
as source text with a virtual repro/ path; never imported."""
import threading


class Registry:
    def __init__(self):
        self._lock = threading.Lock()
        self._table = {}

    def put(self, key, value) -> None:
        with self._lock:
            self._table[key] = value

    def drop(self, key) -> None:
        self._table.pop(key, None)  # ANL006: lock-free write races put()
