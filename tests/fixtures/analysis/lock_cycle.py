"""Seeded concurrency violation (ANL005): an AB/BA lock-order cycle.
`transfer_in` takes ledger -> journal, `transfer_out` takes journal ->
ledger — two threads interleaving these deadlock. Analyzed as source text
with a virtual repro/ path; never imported."""
import threading

_LEDGER_LOCK = threading.Lock()
_JOURNAL_LOCK = threading.Lock()


def transfer_in() -> None:
    with _LEDGER_LOCK:
        with _JOURNAL_LOCK:  # ANL005: edge ledger -> journal
            pass


def transfer_out() -> None:
    with _JOURNAL_LOCK:
        with _LEDGER_LOCK:  # ANL005: reverse edge closes the cycle
            pass
