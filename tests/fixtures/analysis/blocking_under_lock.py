"""Seeded concurrency violation (ANL007): blocking work under a lock.
`snapshot` holds `_STATE_LOCK` across file I/O and a Future wait — every
thread behind the lock stalls on the disk and on the executor. Analyzed
as source text with a virtual repro/ path; never imported."""
import json
import threading

_STATE_LOCK = threading.Lock()
_STATE = {"n": 0}


def snapshot(path, future) -> None:
    with _STATE_LOCK:
        with open(path, "w") as f:  # ANL007: file I/O under the lock
            json.dump(_STATE, f)  # ANL007: and the dump itself
        future.result()  # ANL007: Future wait under the lock
