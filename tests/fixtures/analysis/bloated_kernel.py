"""Seeded pallas-audit violation: a kfu-style kernel whose OUTPUT BlockSpec
has a constant index map over the ENTIRE (N, M) array — the whole result
stays resident in VMEM across the grid instead of streaming tile by tile.
The audit must report exactly one VMEM001 finding under a mock budget
smaller than the resident block (and nothing under the real budget at
these sizes, where the 4 MB residency still fits 16 MiB)."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N, TILE_M = 32, 128


def _kernel(x_ref, z_ref, o_ref, *, ct):
    xs = x_ref[...].astype(ct)
    zs = z_ref[...].astype(ct)
    d2 = ((xs[:, None, :] - zs[None, :, :]) ** 2).sum(-1)
    o_ref[...] = jnp.zeros(o_ref.shape, o_ref.dtype).at[:xs.shape[0],
                                                        :zs.shape[0]].set(
        jnp.exp(-0.5 * d2).astype(o_ref.dtype))


@jax.jit
def bloated_kfu(X, Z):
    N, Q = X.shape
    M = Z.shape[0]
    grid = (N // TILE_N, M // TILE_M)
    return pl.pallas_call(
        functools.partial(_kernel, ct=jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_N, Q), lambda i, j: (i, 0)),
                  pl.BlockSpec((TILE_M, Q), lambda i, j: (j, 0))],
        # the bug: constant index map => the full (N, M) output is resident
        out_specs=pl.BlockSpec((N, M), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, M), jnp.float32),
        interpret=True,
    )(X, Z)
