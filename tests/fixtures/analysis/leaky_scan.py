"""Seeded jaxpr-check violation: a chunked loss whose scan body LEAKS the
per-chunk (chunk, M) kernel block through the scan's stacked ys — so the
trace materializes an (N, M) residual even though the accumulation itself
is chunked. `assert_no_scaling(..., worse_than="N*M")` must flag exactly
this stacked output."""
import jax
import jax.numpy as jnp

CHUNK = 256


def leaky_chunked_loss(X, Z):
    def body(acc, xb):
        K = jnp.exp(-((xb[:, None, :] - Z[None, :, :]) ** 2).sum(-1))
        return acc + K.sum(), K  # the leak: K rides out through ys

    acc, Ks = jax.lax.scan(body, 0.0, X.reshape(-1, CHUNK, X.shape[-1]))
    return acc + Ks.mean()


def clean_chunked_loss(X, Z):
    def body(acc, xb):
        K = jnp.exp(-((xb[:, None, :] - Z[None, :, :]) ** 2).sum(-1))
        return acc + K.sum(), None

    acc, _ = jax.lax.scan(body, 0.0, X.reshape(-1, CHUNK, X.shape[-1]))
    return acc
